"""Crash-safe decode: failure taxonomy, lease detection, KV checkpoints.

Covers the failure-domain contract (docs/failure-model.md):

* ``Scheduler.on_evict`` is IDEMPOTENT and records the failure class;
* the KV_CKPT plane-op lifecycle meters to parity (commit/complete/
  abort/drop_worker) and is stale-safe;
* :class:`FailureDetector` converts a silent crash into an eviction
  within one lease interval, and a hang within the step watchdog;
* a crash victim with a landed checkpoint resumes from it, wasting
  strictly fewer decode tokens than the restart-fresh baseline at equal
  completed work, with zero slot/byte leaks;
* voided snapshots (holder died) are metered as ``kv_lost``;
* :class:`FaultInjector` victim selection is seed-deterministic and its
  transfer faults drive the abort-refund-retry path to completion.
"""
import pytest

from repro.core import WarmPoolPolicy
from repro.cluster import (Application, FailureDetector, FaultInjector,
                           Scheduler, make_sim)
from repro.cluster.traces import Fault, fault_schedule

from test_forecast import A10, AP, RECIPE

LEASE_S = 15.0


def _pool(n, **kw):
    sched, ex, fac = make_sim(devices=[A10] * 4, workers_per_zone=2, **kw)
    fac.reconcile(n)
    return sched, ex, fac


class TestOnEvictIdempotent:
    def test_double_eviction_is_a_noop(self):
        sched, ex, fac = _pool(4)
        wid = next(iter(sched.workers))
        sched.on_evict(wid, 5.0, cause="crash")
        log_n = len(sched.failure_log)
        evi = dict(sched.pool_evictions)
        causes = dict(sched.evictions_by_cause)
        assert sched.on_evict(wid, 6.0, cause="crash") == []
        assert len(sched.failure_log) == log_n
        assert sched.pool_evictions == evi
        assert sched.evictions_by_cause == causes

    def test_double_eviction_mid_run_requeues_once(self):
        sched, ex, fac = _pool(4, warm_pool=WarmPoolPolicy())
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=64,
                                    arrival_s=0.0) for _ in range(4)])
        ex.loop.run(until=30.0)
        wid = next(wid for _, wid in sched.running.values())
        first = sched.on_evict(wid, 30.0)
        assert first, "eviction of the batch host requeued nothing"
        lanes_n = sum(len(lane) for lane in sched.lanes.values())
        assert sched.on_evict(wid, 31.0) == []
        assert sum(len(lane) for lane in sched.lanes.values()) == lanes_n
        ex.run()
        assert sched.done

    def test_cause_recorded(self):
        sched, ex, fac = _pool(3)
        wids = list(sched.workers)
        sched.on_evict(wids[0], 1.0, cause="crash")
        sched.on_evict(wids[1], 2.0, cause="hang")
        sched.on_evict(wids[2], 3.0)                # default: revoke
        assert sched.evictions_by_cause == {"crash": 1, "hang": 1,
                                            "revoke": 1}
        assert [c for _, _, c in sched.failure_log] == \
            ["crash", "hang", "revoke"]


class TestKvCkptPlane:
    def test_lifecycle_meters_to_parity(self):
        plane = Scheduler().plane
        op = plane.kv_ckpt_op("k", "wA", "wB", 1000,
                              src_zone="z0", dst_zone="z1")
        assert plane.ckpt_admits(op, 0.0)
        plane.commit_kv_ckpt(7, op)
        assert plane.inflight_ops == 1
        assert plane.planned.as_dict() != plane.moved.as_dict()
        plane.kv_ckpt_completed(7)
        assert plane.inflight_ops == 0
        assert plane.kv_ckpt == {"z1": 1000}
        assert plane.kv_ckpt_events == 1
        assert plane.planned.as_dict() == plane.moved.as_dict()
        plane.kv_ckpt_completed(7)                  # stale: no-op
        assert plane.kv_ckpt_events == 1

    def test_abort_refunds_and_is_idempotent(self):
        plane = Scheduler().plane
        op = plane.kv_ckpt_op("k", "wA", "wB", 500,
                              src_zone="z0", dst_zone="z1")
        plane.commit_kv_ckpt(8, op)
        plane.kv_ckpt_aborted(8)
        plane.kv_ckpt_aborted(8)
        assert plane.inflight_ops == 0
        assert plane.kv_ckpt == {}
        assert plane.planned.as_dict() == plane.moved.as_dict()

    def test_drop_worker_aborts_either_endpoint(self):
        for dead in ("wA", "wB"):                   # src, then dst
            plane = Scheduler().plane
            op = plane.kv_ckpt_op("k", "wA", "wB", 500,
                                  src_zone="z0", dst_zone="z1")
            plane.commit_kv_ckpt(9, op)
            plane.drop_worker(dead, 0.0)
            assert plane.inflight_ops == 0, f"dead={dead}"
            assert plane.planned.as_dict() == plane.moved.as_dict()

    def test_duplicate_inflight_rid_rejected(self):
        plane = Scheduler().plane
        op = plane.kv_ckpt_op("k", "wA", "wB", 500,
                              src_zone="z0", dst_zone="z1")
        plane.commit_kv_ckpt(1, op)
        with pytest.raises(AssertionError):
            plane.commit_kv_ckpt(1, op)


class TestFailureDetector:
    def test_crash_detected_within_one_lease(self):
        sched, ex, fac = _pool(4)
        det = FailureDetector(ex, lease_s=LEASE_S)
        wid = next(iter(sched.workers))
        det.crash(wid, now=3.0)
        assert wid in sched.workers, \
            "a silent crash must not be visible before the lease expires"
        ex.loop.run(until=3.0 + LEASE_S + 1.0)
        assert wid not in sched.workers
        (w, cause, t_fault, t_detect), = det.detection_log
        assert (w, cause) == (wid, "crash")
        assert 0.0 < t_detect - t_fault <= LEASE_S + 1e-9
        assert sched.evictions_by_cause == {"crash": 1}

    def test_hang_evicted_by_watchdog(self):
        sched, ex, fac = _pool(4)
        det = FailureDetector(ex, lease_s=LEASE_S)   # watchdog 2x lease
        wid = next(iter(sched.workers))
        det.hang(wid, now=0.0)
        ex.loop.run(until=det.watchdog_s - 1.0)
        assert wid in sched.workers, "watchdog fired early"
        ex.loop.run(until=det.watchdog_s + 1.0)
        assert wid not in sched.workers
        assert sched.evictions_by_cause == {"hang": 1}
        assert det.detection_log[0][1] == "hang"

    def test_unknown_or_already_frozen_worker_noop(self):
        sched, ex, fac = _pool(2)
        det = FailureDetector(ex, lease_s=LEASE_S)
        det.crash("w-not-there")
        wid = next(iter(sched.workers))
        det.crash(wid, now=0.0)
        det.crash(wid, now=1.0)                      # already frozen
        det.hang(wid, now=1.0)                       # likewise
        ex.loop.run(until=5 * LEASE_S)
        assert len(det.detection_log) == 1

    def test_revoked_before_expiry_not_double_evicted(self):
        sched, ex, fac = _pool(3)
        det = FailureDetector(ex, lease_s=LEASE_S)
        wid = next(iter(sched.workers))
        det.crash(wid, now=0.0)
        sched.on_evict(wid, 2.0)                     # storm got it first
        ex.loop.run(until=3 * LEASE_S)
        assert det.detection_log == []
        assert sched.evictions_by_cause == {"revoke": 1}


_CRASH_CACHE = {}


def _crash_run(ckpt_every, *, seed=3):
    if (ckpt_every, seed) in _CRASH_CACHE:
        return _CRASH_CACHE[ckpt_every, seed]
    trace = [(30.0 * i, 6) for i in range(40)]
    sched, ex, fac = make_sim(devices=[A10] * 4, trace=trace,
                              workers_per_zone=2,
                              warm_pool=WarmPoolPolicy(),
                              ckpt_every_steps=ckpt_every,
                              retry_seed=seed)
    app = Application(sched)
    key = app.register(RECIPE, active_params=AP)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=256,
                                arrival_s=i * 0.1) for i in range(48)])
    det = FailureDetector(ex, lease_s=LEASE_S)
    inj = FaultInjector(ex, fault_schedule(40.0, 60.0, 4, "crash", 3),
                        detector=det, seed=seed)
    inj.arm()
    ex.run()
    _CRASH_CACHE[ckpt_every, seed] = (sched, ex, det)
    return sched, ex, det


class TestCheckpointResume:
    def test_crash_victims_resume_and_waste_less(self):
        ckpt, ex1, det1 = _crash_run(8)
        base, ex0, det0 = _crash_run(None)
        assert ckpt.done and base.done
        assert ckpt.completed_inferences == base.completed_inferences
        assert ckpt.evictions_by_cause.get("crash", 0) > 0
        assert ckpt.ckpt_resumes > 0, "no victim resumed from a ckpt"
        assert ckpt.kv_ckpts > 0 and ckpt.plane.kv_ckpt_events > 0
        assert ckpt.evicted_inferences < base.evicted_inferences
        assert ckpt.makespan() <= base.makespan()
        for sched, ex in ((ckpt, ex1), (base, ex0)):
            assert not sched.running
            assert sched.plane.inflight_ops == 0
            assert sched.plane.planned.as_dict() == \
                sched.plane.moved.as_dict()
            for w in sched.workers.values():
                for lib in w.libraries.values():
                    assert not lib.batch
        for _, cause, t_fault, t_detect in det1.detection_log:
            if cause == "crash":
                assert t_detect - t_fault <= LEASE_S + 1e-9

    def test_checkpoint_plane_meters(self):
        sched, ex, det = _crash_run(8)
        kv = sched.plane.kv_summary()
        assert kv["ckpt_bytes"] > 0 and kv["ckpt_events"] > 0
        # attempts >= landed snapshots >= resumes actually consumed
        assert sched.kv_ckpts >= kv["ckpt_events"] >= sched.ckpt_resumes
        # observability surfaces the checkpoint traffic per zone
        from repro.cluster import format_zone_bytes
        txt = format_zone_bytes(sched.plane, label="t")
        assert "kv crash safety" in txt


class TestKvLostMetered:
    def test_dead_suspension_holder_meters_kv_lost(self):
        sched, ex, fac = _pool(4)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        r = app.make_request(key, decode_steps=4, arrival_s=0.0)
        sched.submit(r)
        w = next(iter(sched.workers.values()))
        r.suspended, r.suspended_on, r.kv_nbytes = True, w.worker_id, 1234
        # production suspensions enter lanes via _requeue, which bumps
        # the scan gate; this white-box setup mutates in place, so
        # mirror the bookkeeping
        sched._suspended_queued += 1
        sched.on_evict(w.worker_id, 1.0, cause="crash")
        ex.pump()                       # route() voids the dead snapshot
        assert sched.plane.kv_lost.get(w.zone) == 1234
        assert sched.plane.kv_lost_events == 1
        assert not r.suspended and r.kv_nbytes == 0
        assert sched.plane.kv_summary()["lost_bytes"] == 1234

    def test_dead_prefill_holder_meters_kv_lost(self):
        from repro.cluster.scheduler import DECODE
        sched, ex, fac = _pool(4, disaggregate=True)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        r = app.make_request(key, decode_steps=4, arrival_s=0.0)
        sched.submit(r)
        w = next(iter(sched.workers.values()))
        r.phase, r.prefill_worker, r.kv_nbytes = DECODE, w.worker_id, 99
        sched.on_evict(w.worker_id, 1.0)
        ex.pump()
        assert sched.plane.kv_lost.get(w.zone) == 99
        assert r.kv_nbytes == 0


class TestFaultInjector:
    def test_victim_selection_is_seed_deterministic(self):
        sched, ex, fac = _pool(8)
        a = FaultInjector(ex, [], detector=None, seed=11)
        b = FaultInjector(ex, [], detector=None, seed=11)
        c = FaultInjector(ex, [], detector=None, seed=12)
        f = Fault(0.0, "revoke", 4)
        va = [w.worker_id for w in a._pick_victims(f)]
        vb = [w.worker_id for w in b._pick_victims(f)]
        c._pick_victims(f)             # different seed: must not raise
        assert va == vb, "same seed must pick the same victims"
        assert len(va) == 4

    def test_crash_without_detector_rejected(self):
        sched, ex, fac = _pool(2)
        with pytest.raises(ValueError):
            FaultInjector(ex, [Fault(1.0, "crash")], detector=None)
        FaultInjector(ex, [Fault(1.0, "revoke")], detector=None)  # fine

    def test_transfer_fault_aborts_and_retries_to_completion(self):
        sched, ex, fac = _pool(3, warm_pool=WarmPoolPolicy())
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=32,
                                    arrival_s=float(i)) for i in range(9)])
        det = FailureDetector(ex, lease_s=LEASE_S)
        inj = FaultInjector(ex, fault_schedule(2.0, 4.0, 30, "transfer",
                                               2),
                            detector=det, seed=0)
        inj.arm()
        ex.run()
        assert sched.done
        hit = sum(n for _, kind, n in inj.fault_log if kind == "transfer")
        if hit:                         # a transfer was in flight to hit
            assert ex.transfer_retries >= 1
        assert sched.plane.inflight_ops == 0
        assert sched.plane.planned.as_dict() == \
            sched.plane.moved.as_dict()
