"""Unit tests for the HLO roofline parsers (no compilation needed)."""
from repro.launch import dryrun as dr


SYNTH = """\
HloModule jit_step

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%inner_body (p: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], bf16[8,128]) tuple(%i, %ag)
}

%outer_body (p: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %ar = f32[16,16]{1,0} all-reduce(%y), to_apply=%add
  %w2 = (s32[], bf16[8,128]) while(%p), condition=%cond2, body=%inner_body, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %t2 = (s32[], bf16[8,128]) tuple(%i2, %gte)
}

ENTRY %main (a: bf16[4,4]) -> bf16[4,4] {
  %a2a = bf16[32,64]{1,0} all-to-all(%a), dimensions={0}
  %w = (s32[], bf16[8,128]) while(%init), condition=%cond, body=%outer_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = bf16[4,4] copy(%a)
}
"""


class TestShapeBytes:
    def test_simple(self):
        assert dr._shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert dr._shape_bytes("f32[16,16]") == 16 * 16 * 4

    def test_tuple(self):
        s = "(f32[2,3]{1,0}, bf16[4]{0})"
        assert dr._shape_bytes(s) == 2 * 3 * 4 + 4 * 2

    def test_scalar_and_unknown(self):
        assert dr._shape_bytes("f32[]") == 4     # scalar = one element
        assert dr._shape_bytes("token[]") == 0   # non-numeric dtype skipped


class TestCollectiveParsing:
    def test_flat_counts(self):
        out = dr.collective_bytes(SYNTH)
        assert out["all-to-all"] == 32 * 64 * 2
        assert out["all-gather"] == 8 * 128 * 2
        assert out["all-reduce"] == 16 * 16 * 4

    def test_computation_split(self):
        comps = dr._computations(SYNTH)
        assert "__entry__" in comps
        assert "inner_body" in comps and "outer_body" in comps
        assert "all-gather" in comps["inner_body"]
        assert "all-gather" not in comps["outer_body"]

    def test_trip_scaling_nested(self):
        out = dr.collective_bytes_scaled(SYNTH)
        # entry: a2a once; outer while x5 { ar once + inner while x3 {ag} }
        assert out["all-to-all"] == 32 * 64 * 2
        assert out["all-reduce"] == 5 * 16 * 16 * 4
        assert out["all-gather"] == 5 * 3 * 8 * 128 * 2


class TestModelFlops:
    def test_kinds(self):
        from repro.configs import get_config, INPUT_SHAPES
        cfg = get_config("olmo-1b")
        n = cfg.n_active_params()
        t = INPUT_SHAPES["train_4k"]
        assert dr.model_flops(cfg, t) == 6.0 * n * t.global_batch * t.seq_len
        d = INPUT_SHAPES["decode_32k"]
        assert dr.model_flops(cfg, d) == 2.0 * n * d.global_batch


def test_baseline_variant_reverts_optimizations():
    from repro.configs import get_config
    ds = dr.baseline_variant(get_config("deepseek-v3-671b"))
    assert ds.moe.dispatch == "sort_scatter"
    assert ds.parallel.seq_parallel
    assert not ds.parallel.context_parallel_decode
    phi = dr.baseline_variant(get_config("phi3.5-moe-42b-a6.6b"))
    assert phi.moe.dispatch == "dense_onehot"
