"""Forecast-aware elastic supply: forecaster, policy, factory, storms."""
import math

import pytest

from repro.core import PERVASIVE, WarmPoolPolicy
from repro.cluster import (Application, ChurnInjector, DemandForecaster,
                           ElasticPolicy, GPU_CATALOG, Scheduler, Storm,
                           Worker, format_pool, make_sim, pool_summary,
                           storm_schedule)
from repro.cluster.scheduler import ARRIVAL_EWMA_TAU_S
from repro.configs import get_config
from repro.core import model_context_recipe

CFG = get_config("smollm2-1.7b")
RECIPE = model_context_recipe(CFG, include_compile=False)
AP = CFG.n_active_params()
A10 = GPU_CATALOG["NVIDIA A10"]


def feed(fc, key, rate, t0, t1):
    """Poisson-free steady arrivals at ``rate``/s over [t0, t1)."""
    t = t0
    while t < t1:
        fc.note(key, t)
        t += 1.0 / rate


class TestDemandForecaster:
    def test_steady_rate_forecast_tracks_rate(self):
        fc = DemandForecaster()
        feed(fc, "k", 5.0, 0.0, 120.0)
        assert fc.trailing_rate("k", 120.0) == pytest.approx(5.0, rel=0.1)
        assert fc.forecast("k", 120.0) == pytest.approx(5.0, rel=0.25)

    def test_rising_trend_extrapolates_above_current(self):
        fc = DemandForecaster(burst_factor=100.0)   # burst detector off
        for i in range(12):                         # 1/s .. 12/s ramp
            feed(fc, "k", float(i + 1), i * 10.0, (i + 1) * 10.0)
        now = 120.0
        assert fc.forecast("k", now) > fc.trailing_rate("k", now)

    def test_burst_pins_forecast_then_expires(self):
        fc = DemandForecaster(burst_hold_s=60.0)
        feed(fc, "k", 1.0, 0.0, 100.0)
        feed(fc, "k", 12.0, 100.0, 110.0)           # 12x jump
        assert fc.burst_active("k", 110.0)
        assert fc.forecast("k", 110.0) >= 10.0
        # no further arrivals: the pin holds, then expires
        assert fc.forecast("k", 150.0) >= 10.0
        assert not fc.burst_active("k", 300.0)
        assert fc.forecast("k", 300.0) < 2.0

    def test_redetection_extends_and_raises_pin(self):
        fc = DemandForecaster(burst_hold_s=60.0)
        feed(fc, "k", 1.0, 0.0, 100.0)
        n0 = fc.bursts_detected          # cold start may count as one
        feed(fc, "k", 10.0, 100.0, 104.0)
        assert fc.bursts_detected == n0 + 1
        hold0 = fc._burst["k"][0]
        feed(fc, "k", 20.0, 104.0, 108.0)           # raise mid-burst
        assert fc.bursts_detected == n0 + 1         # same burst, extended
        assert fc._burst["k"][0] > hold0
        assert fc.forecast("k", 108.0) >= 15.0

    def test_min_burst_events_guards_fresh_window(self):
        fc = DemandForecaster(min_burst_events=4)
        # long steady feed so the cold-start pin (0 -> 1/s is a jump
        # too) has expired by the probe time
        feed(fc, "k", 1.0, 0.0, 300.0)
        assert not fc.burst_active("k", 300.0)
        n0 = fc.bursts_detected
        fc.note("k", 300.0)                         # 1 event, new window
        assert not fc.burst_active("k", 300.1)
        assert fc.bursts_detected == n0

    def test_idle_recipe_decays_to_zero(self):
        fc = DemandForecaster()
        feed(fc, "k", 8.0, 0.0, 60.0)
        assert fc.forecast("k", 60.0) > 4.0
        # 12 empty windows later the series is all zeros
        assert fc.forecast("k", 60.0 + 12 * 10.0 + 5.0) == 0.0

    def test_snapshot_covers_all_keys(self):
        fc = DemandForecaster()
        feed(fc, "a", 2.0, 0.0, 50.0)
        feed(fc, "b", 4.0, 0.0, 50.0)
        snap = fc.snapshot(50.0)
        assert set(snap) == {"a", "b"}
        assert snap["b"] > snap["a"]


class TestEwmaStaleness:
    """Satellite: ClusterView EWMAs decay to the read time — a recipe
    that stopped arriving no longer reports its last-event rate."""

    def _sched_with_arrivals(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        app = Application(sched)
        for i in range(60):
            sched.submit(app.make_request(key, decode_steps=1,
                                          arrival_s=i * 0.5))
        return sched, key

    def test_view_rate_decays_without_new_events(self):
        sched, key = self._sched_with_arrivals()
        at_end = sched.view(30.0).arrival_rate[key]
        later = sched.view(30.0 + ARRIVAL_EWMA_TAU_S).arrival_rate[key]
        much_later = sched.view(30.0 + 5 * ARRIVAL_EWMA_TAU_S) \
            .arrival_rate[key]
        assert at_end > 1.0
        assert later == pytest.approx(at_end * math.exp(-1.0), rel=1e-6)
        assert much_later < 0.02 * at_end

    def test_read_is_pure(self):
        sched, key = self._sched_with_arrivals()
        first = sched.view(100.0).arrival_rate[key]
        again = sched.view(100.0).arrival_rate[key]
        assert first == again
        # reading at a later time did not corrupt the stored snapshot
        sched.view(1000.0)
        assert sched.view(100.0).arrival_rate[key] == first

    def test_view_publishes_forecast_and_units(self):
        sched, key = self._sched_with_arrivals()
        v = sched.view(30.0)
        assert v.forecast_rate[key] > 0
        prompt_mean, decode_mean = v.request_units[key]
        assert prompt_mean >= 0.0 and decode_mean == 1.0
        assert v.backlog_units[key] > 0          # nothing ran yet


class _FakeView:
    def __init__(self, rate, *, backlog=0.0, units=(1.0, 6.0)):
        self.forecast_rate = {"k": rate}
        self.arrival_rate = {"k": rate}
        self.backlog_units = {"k": backlog}
        self.request_units = {"k": units}
        self.demand = {"k": 1}


class TestElasticPolicy:
    def _policy(self, **kw):
        return ElasticPolicy(supply=[A10], active_params=AP, **kw)

    def test_target_scales_with_demand(self):
        pol = self._policy()
        lo = pol.target_workers(_FakeView(2.0))
        hi = pol.target_workers(_FakeView(20.0))
        assert 0 < lo < hi

    def test_backlog_adds_capacity(self):
        pol = self._policy()
        assert pol.target_workers(_FakeView(2.0, backlog=5000.0)) \
            > pol.target_workers(_FakeView(2.0))

    def test_decide_never_exceeds_ceiling(self):
        pol = self._policy()
        assert pol.decide(_FakeView(1000.0), current=4, ceiling=10,
                          now=0.0) <= 10

    def test_ceiling_breach_sheds_immediately(self):
        pol = self._policy()
        pol.decide(_FakeView(1000.0), current=4, ceiling=50, now=0.0)
        # a ceiling drop below the pool size bypasses band AND cooldown
        assert pol.decide(_FakeView(1000.0), current=40, ceiling=8,
                          now=1.0) == 8

    def test_hysteresis_dead_band_holds(self):
        pol = self._policy(hysteresis=0.5)
        view = _FakeView(2.0)
        want = pol.target_workers(view)
        cur = want + 1                   # within 50% of the raw target
        assert pol.decide(view, current=cur, ceiling=100,
                          now=1000.0) == cur

    def test_shared_cooldown_blocks_flip_flop(self):
        pol = self._policy(hysteresis=0.0)
        up = pol.decide(_FakeView(50.0), current=1, ceiling=100, now=0.0)
        assert up > 1
        # demand collapses right after the acquire: release must wait a
        # full release_cooldown_s from the acquire
        t = pol.release_cooldown_s - 1.0
        assert pol.decide(_FakeView(0.01), current=up, ceiling=100,
                          now=t) == up
        assert pol.decide(_FakeView(0.01), current=up, ceiling=100,
                          now=pol.release_cooldown_s + 1.0) < up

    def test_rejects_unknown_signal(self):
        with pytest.raises(ValueError):
            ElasticPolicy(signal="oracle")

    def test_ewma_signal_reads_arrival_rate(self):
        pol = self._policy(signal="ewma")
        v = _FakeView(10.0)
        v.forecast_rate = {"k": 0.0}     # forecast says idle; EWMA not
        assert pol.target_workers(v) > 1


def run_elastic(arrival_rate=10.0, n=300, ceiling=12, until=None,
                storms=(), suppress_s=0.0, **policy_kw):
    policy = ElasticPolicy(signal="forecast", active_params=AP,
                           **policy_kw)
    sched, ex, fac = make_sim(devices=[A10] * 4, trace=[(0.0, ceiling)],
                              warm_pool=WarmPoolPolicy(),
                              policy=policy, tick_s=5.0)
    app = Application(sched)
    key = app.register(RECIPE, active_params=AP)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=4,
                                arrival_s=i / arrival_rate)
                           for i in range(n)])
    inj = ChurnInjector(ex, storms, factory=fac, seed=7,
                        suppress_s=suppress_s)
    inj.arm()
    ex.run(until=until)
    return sched, ex, fac, inj


class TestFactoryElasticMode:
    def test_pool_sized_by_demand_within_ceiling(self):
        sched, ex, fac, _ = run_elastic(ceiling=6)
        assert sched.done
        assert fac.scale_log, "the policy never scaled the pool"
        assert 0 < len(sched.workers) <= 6
        assert fac.acquire_log, "acquires were not stamped"

    def test_pool_releases_when_demand_decays(self):
        # a dense burst then a sparse trickle: the forecast decays, the
        # policy releases mid-run (the trickle keeps the sim alive)
        policy = ElasticPolicy(signal="forecast", active_params=AP)
        sched, ex, fac = make_sim(devices=[A10] * 4,
                                  trace=[(0.0, 12)],
                                  warm_pool=WarmPoolPolicy(),
                                  policy=policy, tick_s=5.0)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        specs = [dict(recipe_key=key, decode_steps=4, arrival_s=i / 10.0)
                 for i in range(300)]
        specs += [dict(recipe_key=key, decode_steps=1,
                       arrival_s=100.0 + 60.0 * i) for i in range(8)]
        app.submit_stream(ex, specs)
        ex.run()
        assert sched.done
        peak = max(to for (_, _, to) in fac.scale_log)
        assert fac.target < peak, "pool never released after the burst"
        assert any(to < frm for (_, frm, to) in fac.scale_log)

    def test_restriction_lowers_effective_ceiling_until_expiry(self):
        sched, ex, fac, _ = run_elastic(until=1.0, ceiling=10)
        fac.restrict(4, until_s=50.0)
        assert fac.effective_ceiling(10.0) == 6
        assert fac.effective_ceiling(60.0) == 10   # lapsed

    def test_storm_recovers_without_leaks(self):
        sched, ex, fac, inj = run_elastic(
            n=600, storms=[Storm(20.0, 3, zone_correlated=True)],
            suppress_s=10.0)
        assert inj.killed == 3
        assert sched.done
        plane = sched.plane
        assert plane.inflight_ops == 0
        assert plane.planned.as_dict() == plane.moved.as_dict()
        for w in sched.workers.values():
            for lib in w.libraries.values():
                assert not lib.batch

    def test_legacy_trace_mode_unchanged(self):
        # no policy: the factory tracks the trace exactly as before
        sched, ex, fac = make_sim(devices=[A10] * 4,
                                  trace=[(0.0, 3), (50.0, 1)])
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 500, 50, PERVASIVE, active_params=AP)
        ex.run()
        assert sched.done
        assert fac.policy is None and fac.scale_log == []


class TestChurnInjector:
    def _pool(self, n, workers_per_zone=4):
        sched, ex, fac = make_sim(devices=[A10] * 4,
                                  workers_per_zone=workers_per_zone)
        fac.reconcile(n)
        return sched, ex

    def test_zone_correlated_drains_one_zone_first(self):
        sched, ex = self._pool(12, workers_per_zone=4)   # z0 z1 z2
        inj = ChurnInjector(ex, [Storm(0.0, 4)], seed=0)
        victims = inj._pick_victims(Storm(0.0, 4))
        assert len(victims) == 4
        assert len({w.zone for w in victims}) == 1, \
            "4 victims from a 4-per-zone pool must share one zone"

    def test_zone_spill_by_population(self):
        sched, ex = self._pool(6, workers_per_zone=4)    # z0 x4, z1 x2
        inj = ChurnInjector(ex, [], seed=1)
        victims = inj._pick_victims(Storm(0.0, 6))
        assert len(victims) == 6                         # whole pool

    def test_revoke_staging_picks_staging_first(self):
        sched, ex = self._pool(6)
        staged = list(sched.workers.values())[2]
        staged.staging = True
        inj = ChurnInjector(ex, [], seed=0)
        victims = inj._pick_victims(Storm(0.0, 1, revoke_staging=True))
        assert victims == [staged]

    def test_fire_evicts_and_logs(self):
        sched, ex = self._pool(8)
        inj = ChurnInjector(ex, [Storm(5.0, 3)], seed=0)
        inj.arm()
        ex.loop.run(until=10.0)
        assert inj.killed == 3
        assert len(sched.workers) == 5
        assert inj.storm_log == [(5.0, 3)]

    def test_arm_twice_rejected(self):
        sched, ex = self._pool(2)
        inj = ChurnInjector(ex, [], seed=0)
        inj.arm()
        with pytest.raises(AssertionError):
            inj.arm()

    def test_storm_schedule_builder(self):
        train = storm_schedule(100.0, 50.0, 3, 8, revoke_staging=True)
        assert [s.t_s for s in train] == [100.0, 150.0, 200.0]
        assert all(s.n_workers == 8 and s.revoke_staging for s in train)


class TestPoolObservability:
    def test_join_evict_counters_by_class(self):
        sched = Scheduler()
        sched.add_worker(Worker(A10, zone="z0"))
        w2 = Worker(A10, zone="z0")
        sched.add_worker(w2)
        sched.on_evict(w2.worker_id)
        s = pool_summary(sched)
        assert s["joins"] == {"NVIDIA A10": 2}
        assert s["evictions"] == {"NVIDIA A10": 1}
        assert s["by_class"]["NVIDIA A10"] == 1

    def test_summary_with_factory_has_targets_and_lead(self):
        sched, ex, fac, _ = run_elastic(ceiling=6)
        s = pool_summary(sched, fac)
        assert s["target"] == fac.target
        assert s["ceiling"] == 6
        assert s["n_acquired"] == len(fac.acquire_log)
        assert s["n_warmed"] > 0
        assert s["acquire_lead_p50_s"] >= 0.0
        text = format_pool(s, label="t")
        assert "target" in text and "NVIDIA A10" in text

    def test_format_pool_without_factory(self):
        sched = Scheduler()
        sched.add_worker(Worker(A10, zone="z0"))
        text = format_pool(pool_summary(sched))
        assert "1 worker" in text and "target" not in text
