"""Hillclimb harness: lower one (arch × shape), print roofline terms and the
top collective contributors (trip-scaled), so each hypothesis→change cycle
has an op-level profile to reason from.

  PYTHONPATH=src python experiments/hillclimb.py llama3-405b train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import re
import sys

import jax

from repro.launch.dryrun import (_computations, _shape_bytes, _TRIP_RE,
                                 _WHILE_BODY_RE, _COLLECTIVES, dryrun_one)


def top_collectives(hlo_text: str, k: int = 14):
    comps = _computations(hlo_text)
    # computation -> multiplier (product of enclosing trip counts)
    mult = {"__entry__": 1}
    frontier = ["__entry__"]
    while frontier:
        name = frontier.pop()
        text = comps.get(name, "")
        for line in text.splitlines():
            if " while(" not in line:
                continue
            mb = _WHILE_BODY_RE.search(line)
            mt = _TRIP_RE.search(line)
            if mb and mb.group(1) in comps:
                trip = int(mt.group(1)) if mt else 1
                mult[mb.group(1)] = mult.get(name, 1) * trip
                frontier.append(mb.group(1))
    rows = []
    for name, text in comps.items():
        if name == "__entry__" or name not in mult:
            m = mult.get(name)
            if m is None:
                continue
        m = mult[name]
        for line in text.splitlines():
            ls = line.strip()
            mm = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+([\w-]+)", ls)
            if not mm:
                continue
            op = mm.group(2).rstrip(".0123456789")
            if op in _COLLECTIVES:
                b = _shape_bytes(mm.group(1)) * m
                meta = re.search(r'op_name="([^"]*)"', ls)
                rows.append((b, op, mm.group(1)[:60], m,
                             (meta.group(1)[-70:] if meta else "")))
    rows.sort(reverse=True)
    return rows[:k]


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    import repro.launch.dryrun as dr
    # optional ParallelConfig overrides: key=value pairs after the shape
    if len(sys.argv) > 3:
        import dataclasses
        from repro.configs import ARCH_REGISTRY, get_config
        cfg = get_config(arch)
        kw = {}
        for kv in sys.argv[3:]:
            k, v = kv.split("=")
            kw[k] = {"True": True, "False": False}.get(v) \
                if v in ("True", "False") else (int(v) if v.isdigit() else v)
        cfg = cfg.with_(parallel=dataclasses.replace(cfg.parallel, **kw))
        ARCH_REGISTRY[arch] = cfg
        print(f"overrides: {kw}")
    # capture the HLO text by monkey-wrapping collective_bytes_scaled
    captured = {}
    orig = dr.collective_bytes_scaled

    def wrap(text):
        captured["hlo"] = text
        return orig(text)

    dr.collective_bytes_scaled = wrap
    rec = dryrun_one(arch, shape, verbose=False)
    dr.collective_bytes_scaled = orig
    print(f"== {arch} × {shape} ==")
    for kk in ("compute_s", "memory_s", "collective_s", "bottleneck",
               "hlo_flops", "hbm_bytes", "collective_bytes",
               "useful_flops_frac"):
        print(f"  {kk}: {rec[kk]}")
    print("\ntop collectives (trip-scaled bytes):")
    for b, op, shp, m, meta in top_collectives(captured["hlo"]):
        print(f"  {b/1e9:9.1f} GB  x{m:<4d} {op:20s} {shp:60s} {meta}")


if __name__ == "__main__":
    main()
